//! ZGEMM via the 4M method (§9: "it is straightforward to extend the
//! emulation of DGEMM, including the ADP framework, to ZGEMM via the 4M
//! method" — Van Zee & Smith, ACM TOMS 2017).
//!
//! A complex GEMM C = A·B decomposes into four real GEMMs on the
//! real/imaginary parts:
//!
//! ```text
//! C_re = A_re B_re - A_im B_im
//! C_im = A_re B_im + A_im B_re
//! ```
//!
//! Each real product is dispatched through a [`GemmBackend`], so plugging
//! in an [`crate::coordinator::AdpEngine`] yields guaranteed-accuracy
//! emulated ZGEMM with per-product guardrails (each of the four products
//! gets its own scan/ESC/fallback decision).

use super::matrix::Matrix;
use super::qr::GemmBackend;

/// A dense complex matrix as split real/imaginary planes.
#[derive(Clone, Debug, PartialEq)]
pub struct ZMatrix {
    pub re: Matrix,
    pub im: Matrix,
}

impl ZMatrix {
    pub fn zeros(rows: usize, cols: usize) -> ZMatrix {
        ZMatrix { re: Matrix::zeros(rows, cols), im: Matrix::zeros(rows, cols) }
    }

    pub fn rows(&self) -> usize {
        self.re.rows
    }

    pub fn cols(&self) -> usize {
        self.re.cols
    }

    pub fn from_fn(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> (f64, f64),
    ) -> ZMatrix {
        let mut re = Matrix::zeros(rows, cols);
        let mut im = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                let (r, x) = f(i, j);
                *re.at_mut(i, j) = r;
                *im.at_mut(i, j) = x;
            }
        }
        ZMatrix { re, im }
    }

    /// Reference product in double-double precision (both planes).
    pub fn matmul_dd(&self, other: &ZMatrix) -> ZMatrix {
        let rr = self.re.matmul_dd(&other.re);
        let ii = self.im.matmul_dd(&other.im);
        let ri = self.re.matmul_dd(&other.im);
        let ir = self.im.matmul_dd(&other.re);
        let mut re = rr;
        let mut im = ri;
        for idx in 0..re.data.len() {
            re.data[idx] -= ii.data[idx];
            im.data[idx] += ir.data[idx];
        }
        ZMatrix { re, im }
    }

    pub fn max_abs(&self) -> f64 {
        self.re.max_abs().max(self.im.max_abs())
    }
}

/// C = A * B through four backend GEMMs (the 4M decomposition).
pub fn zgemm(a: &ZMatrix, b: &ZMatrix, backend: &mut dyn GemmBackend) -> ZMatrix {
    assert_eq!(a.re.cols, b.re.rows, "zgemm shape mismatch");
    let rr = backend.gemm(&a.re, &b.re);
    let ii = backend.gemm(&a.im, &b.im);
    let ri = backend.gemm(&a.re, &b.im);
    let ir = backend.gemm(&a.im, &b.re);
    let mut re = rr;
    re.data.iter_mut().zip(&ii.data).for_each(|(x, y)| *x -= y);
    let mut im = ri;
    im.data.iter_mut().zip(&ir.data).for_each(|(x, y)| *x += y);
    ZMatrix { re, im }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::heuristic::AlwaysEmulate;
    use crate::coordinator::{AdpConfig, AdpEngine};
    use crate::linalg::NativeGemm;
    use crate::util::Rng;

    fn rand_z(n: usize, rng: &mut Rng) -> ZMatrix {
        ZMatrix::from_fn(n, n, |_, _| (rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
    }

    #[test]
    fn zgemm_matches_dd_reference_native() {
        let mut rng = Rng::new(300);
        let a = rand_z(24, &mut rng);
        let b = rand_z(24, &mut rng);
        let c = zgemm(&a, &b, &mut NativeGemm);
        let c_ref = a.matmul_dd(&b);
        let scale = c_ref.max_abs();
        for idx in 0..c.re.data.len() {
            assert!((c.re.data[idx] - c_ref.re.data[idx]).abs() < 1e-13 * scale);
            assert!((c.im.data[idx] - c_ref.im.data[idx]).abs() < 1e-13 * scale);
        }
    }

    #[test]
    fn zgemm_through_adp_engine() {
        // The paper's §9 extension: emulated ZGEMM with guardrails.
        let mut engine = AdpEngine::new(
            AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_runtime(None),
        );
        let mut rng = Rng::new(301);
        let a = rand_z(16, &mut rng);
        let b = rand_z(16, &mut rng);
        let c = zgemm(&a, &b, &mut engine);
        let c_ref = a.matmul_dd(&b);
        let scale = c_ref.max_abs();
        for idx in 0..c.re.data.len() {
            assert!((c.re.data[idx] - c_ref.re.data[idx]).abs() < 1e-13 * scale);
            assert!((c.im.data[idx] - c_ref.im.data[idx]).abs() < 1e-13 * scale);
        }
        // all four component products dispatched through ADP
        assert_eq!(engine.metrics.snapshot().requests, 4);
        assert_eq!(engine.metrics.snapshot().emulated, 4);
    }

    #[test]
    fn zgemm_guardrails_on_complex_nan() {
        let mut engine = AdpEngine::new(
            AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_runtime(None),
        );
        let mut rng = Rng::new(302);
        let mut a = rand_z(8, &mut rng);
        let b = rand_z(8, &mut rng);
        *a.im.at_mut(2, 2) = f64::NAN; // NaN only in the imaginary plane
        let c = zgemm(&a, &b, &mut engine);
        // imaginary-plane products fall back and propagate the NaN
        assert!(c.re.has_non_finite() || c.im.has_non_finite());
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.fallback_nan, 2); // A_im*B_im and A_im*B_re
        assert_eq!(snap.emulated, 2);
    }

    #[test]
    fn pure_real_inputs_reduce_to_dgemm() {
        let mut rng = Rng::new(303);
        let ar = crate::linalg::Matrix::uniform(10, 10, -1.0, 1.0, &mut rng);
        let br = crate::linalg::Matrix::uniform(10, 10, -1.0, 1.0, &mut rng);
        let a = ZMatrix { re: ar.clone(), im: Matrix::zeros(10, 10) };
        let b = ZMatrix { re: br.clone(), im: Matrix::zeros(10, 10) };
        let c = zgemm(&a, &b, &mut NativeGemm);
        assert_eq!(c.re, crate::linalg::gemm(&ar, &br));
        assert_eq!(c.im.max_abs(), 0.0);
    }
}
