//! Dense row-major FP64 matrix.

use crate::dd;
use crate::util::Rng;

/// Dense row-major matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Uniform(lo, hi) entries.
    pub fn uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| rng.uniform(lo, hi))
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// 128-bit content fingerprint over shape + raw bit patterns (two
    /// independent multiplicative hashes). Used as the operand identity of
    /// the `ozaki::batched` slice cache: equal fingerprints are treated as
    /// the same operand, so the pair of streams keeps *accidental*
    /// collision probability negligible (~2^-128 per pair). Bit-pattern
    /// based, so -0.0 != 0.0 and NaN payloads are distinguished —
    /// strictly finer than semantic equality, never coarser.
    ///
    /// These are non-cryptographic hashes: an adversary who controls the
    /// raw operand bits can in principle construct a colliding pair and
    /// poison a shared cache with a wrong decomposition. Deployments that
    /// serve mutually untrusted clients from one cache should disable the
    /// slice cache (`AdpConfig::slice_cache = None`, or a per-tenant
    /// cache) rather than rely on this fingerprint as a security
    /// boundary.
    pub fn fingerprint(&self) -> (u64, u64) {
        let mut h1: u64 = 0xcbf2_9ce4_8422_2325 ^ (self.rows as u64).rotate_left(17);
        let mut h2: u64 = 0x9e37_79b9_7f4a_7c15 ^ (self.cols as u64).rotate_left(31);
        for &x in &self.data {
            let b = x.to_bits();
            h1 = (h1 ^ b).wrapping_mul(0x0000_0100_0000_01b3);
            h2 = (h2 ^ b.rotate_left(32)).wrapping_mul(0xff51_afd7_ed55_8ccd);
        }
        (h1, h2 ^ (h2 >> 29))
    }

    /// Copy of the sub-block [r0, r0+nr) x [c0, c0+nc).
    pub fn block(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Matrix {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols);
        Matrix::from_fn(nr, nc, |i, j| self.at(r0 + i, c0 + j))
    }

    /// Write `b` into the sub-block starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Matrix) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for i in 0..b.rows {
            for j in 0..b.cols {
                *self.at_mut(r0 + i, c0 + j) = b.at(i, j);
            }
        }
    }

    /// Zero-pad to (nr, nc); exact for GEMM operands.
    pub fn pad_to(&self, nr: usize, nc: usize) -> Matrix {
        assert!(nr >= self.rows && nc >= self.cols);
        let mut out = Matrix::zeros(nr, nc);
        out.set_block(0, 0, self);
        out
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// |self| elementwise.
    pub fn abs(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x.abs()).collect(),
        }
    }

    /// Reference product in double-double precision, rounded to f64.
    /// O(n^3) with ~106-bit accumulation — the C_ref of the grading tests.
    pub fn matmul_dd(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let bt = other.transpose();
        Matrix::from_fn(self.rows, other.cols, |i, j| {
            dd::dot(self.row(i), bt.row(j)).to_f64()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_roundtrip() {
        let m = Matrix::from_fn(6, 5, |i, j| (i * 10 + j) as f64);
        let b = m.block(2, 1, 3, 2);
        assert_eq!(b.at(0, 0), 21.0);
        assert_eq!(b.at(2, 1), 42.0);
        let mut m2 = Matrix::zeros(6, 5);
        m2.set_block(2, 1, &b);
        assert_eq!(m2.at(3, 2), 32.0);
        assert_eq!(m2.at(0, 0), 0.0);
    }

    #[test]
    fn pad_preserves_product() {
        let mut rng = Rng::new(1);
        let a = Matrix::uniform(3, 4, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(4, 2, -1.0, 1.0, &mut rng);
        let c = a.matmul_dd(&b);
        let cp = a.pad_to(8, 8).matmul_dd(&b.pad_to(8, 8));
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(c.at(i, j), cp.at(i, j));
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let m = Matrix::uniform(5, 7, 0.0, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn fro_norm_identity() {
        assert!((Matrix::identity(9).fro_norm() - 3.0).abs() < 1e-15);
    }

    #[test]
    fn non_finite_detection() {
        let mut m = Matrix::zeros(2, 2);
        assert!(!m.has_non_finite());
        *m.at_mut(1, 0) = f64::NAN;
        assert!(m.has_non_finite());
        *m.at_mut(1, 0) = f64::INFINITY;
        assert!(m.has_non_finite());
    }
}
