//! Blocked native FP64 GEMM — the "cuBLAS DGEMM" of this substrate.
//!
//! This is the denominator of every speedup the benches report and the
//! fallback target of ADP, so it must not be a strawman: it uses k-panel
//! packing of B, 4-wide j-unrolling with FMA, and cache-sized blocks.
//! Multi-threading happens one level up (the coordinator shards requests);
//! this routine is deliberately single-threaded and deterministic.

use super::matrix::Matrix;

// Cache blocking: MC x KC panel of A (L2), KC x NC panel of B (L3/L2),
// micro-kernel accumulates 1 x NR in registers.
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 256;
const NR: usize = 8;

/// C = A * B.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm_into(a, b, &mut c, 0.0);
    c
}

/// C = A*B + beta*C (beta = 0 overwrites, matching BLAS semantics for the
/// uses in this crate: QR trailing updates call it with beta = 1).
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if beta == 0.0 {
        c.data.fill(0.0);
    } else if beta != 1.0 {
        c.scale(beta);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }

    // Packed KC x NC panel of B, NR-interleaved for the micro-kernel.
    let mut bpack = vec![0.0f64; KC * NC];

    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(b, pc, jc, kc, nc, &mut bpack);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                macro_kernel(a, &bpack, c, ic, pc, jc, mc, kc, nc);
            }
        }
    }
}

/// Pack B[pc..pc+kc, jc..jc+nc] into NR-wide column strips:
/// bpack[strip][l * NR + r] = B[pc+l, jc + strip*NR + r].
#[inline]
fn pack_b(b: &Matrix, pc: usize, jc: usize, kc: usize, nc: usize, bpack: &mut [f64]) {
    let strips = nc.div_ceil(NR);
    for s in 0..strips {
        let j0 = s * NR;
        let w = NR.min(nc - j0);
        let dst = &mut bpack[s * kc * NR..(s + 1) * kc * NR];
        for l in 0..kc {
            let src = b.row(pc + l);
            let d = &mut dst[l * NR..l * NR + NR];
            for r in 0..w {
                d[r] = src[jc + j0 + r];
            }
            for r in w..NR {
                d[r] = 0.0;
            }
        }
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    a: &Matrix,
    bpack: &[f64],
    c: &mut Matrix,
    ic: usize,
    pc: usize,
    jc: usize,
    mc: usize,
    kc: usize,
    nc: usize,
) {
    let strips = nc.div_ceil(NR);
    for i in 0..mc {
        let arow = &a.row(ic + i)[pc..pc + kc];
        for s in 0..strips {
            let j0 = s * NR;
            let w = NR.min(nc - j0);
            let bp = &bpack[s * kc * NR..(s + 1) * kc * NR];
            // 1 x NR register accumulator micro-kernel.
            let mut acc = [0.0f64; NR];
            for (l, &al) in arow.iter().enumerate() {
                let brow = &bp[l * NR..l * NR + NR];
                for r in 0..NR {
                    acc[r] = al.mul_add(brow[r], acc[r]);
                }
            }
            let crow = &mut c.row_mut(ic + i)[jc + j0..jc + j0 + w];
            for r in 0..w {
                crow[r] += acc[r];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for l in 0..a.cols {
                let al = a.at(i, l);
                for j in 0..b.cols {
                    *c.at_mut(i, j) += al * b.at(l, j);
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_square() {
        let mut rng = Rng::new(3);
        for n in [1, 2, 7, 16, 33, 65, 130] {
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let c = gemm(&a, &b);
            let r = naive(&a, &b);
            let err = c.sub(&r).max_abs();
            assert!(err < 1e-12 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn matches_naive_rectangular() {
        let mut rng = Rng::new(4);
        for (m, k, n) in [(3, 300, 5), (100, 7, 260), (65, 257, 9), (1, 1, 1)] {
            let a = Matrix::uniform(m, k, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(k, n, -1.0, 1.0, &mut rng);
            let err = gemm(&a, &b).sub(&naive(&a, &b)).max_abs();
            assert!(err < 1e-11, "({m},{k},{n}) err={err}");
        }
    }

    #[test]
    fn beta_accumulates() {
        let mut rng = Rng::new(5);
        let a = Matrix::uniform(20, 30, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(30, 10, -1.0, 1.0, &mut rng);
        let mut c = Matrix::uniform(20, 10, -1.0, 1.0, &mut rng);
        let c0 = c.clone();
        gemm_into(&a, &b, &mut c, 1.0);
        let mut expect = naive(&a, &b);
        expect.add_assign(&c0);
        assert!(c.sub(&expect).max_abs() < 1e-12);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(6);
        let a = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
        let c = gemm(&a, &Matrix::identity(40));
        assert!(c.sub(&a).max_abs() == 0.0);
    }

    #[test]
    fn empty_dims() {
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        let c = gemm(&a, &b);
        assert_eq!((c.rows, c.cols), (0, 3));
    }
}
