//! Blocked native FP64 GEMM — the "cuBLAS DGEMM" of this substrate.
//!
//! This is the denominator of every speedup the benches report and the
//! fallback target of ADP, so it must not be a strawman: it uses k-panel
//! packing of B, 4-wide j-unrolling with FMA, and cache-sized blocks.
//!
//! The loop nest is organized as a grid of MC×NC output tiles
//! ([`tile_grid`]), each accumulated over the full k extent by the one
//! reference micro-kernel ([`gemm_tile`]). Per C element the floating-point
//! operation sequence depends only on its own tile's k-panel walk — never
//! on which thread runs the tile or in which order tiles complete — which
//! is what lets `backend::ParallelBackend` fan the grid out across threads
//! while staying **bitwise identical** to this serial schedule. `gemm` /
//! `gemm_into` here stay single-threaded and deterministic; parallelism is
//! opted into one level up via the `backend` layer.

use super::matrix::Matrix;

// Cache blocking: MC x KC panel of A (L2), KC x NC panel of B (L3/L2),
// micro-kernel accumulates 1 x NR in registers.
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 256;
const NR: usize = 8;

/// C = A * B.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm_into(a, b, &mut c, 0.0);
    c
}

/// C = A*B + beta*C (beta = 0 overwrites, matching BLAS semantics for the
/// uses in this crate: QR trailing updates call it with beta = 1).
///
/// Serial schedule: jc → pc → ic, packing each B panel once and reusing
/// it across all MC row blocks (cheaper than the per-tile packing of
/// [`gemm_tile`], which pays that to make tiles independent). Per C
/// element both schedules execute the identical FP op sequence, which the
/// backend layer's bitwise property test asserts.
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    apply_beta(c, beta);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut bpack = vec![0.0f64; PACK_LEN];
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(b, pc, jc, kc, nc, &mut bpack);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                macro_kernel(a, &bpack, c, ic, pc, jc, mc, kc, nc);
            }
        }
    }
}

/// The packed-panel micro-kernel of the serial schedule, writing straight
/// into C. MUST stay operation-identical to the strip loop in
/// [`gemm_tile`] — the bitwise serial/parallel equivalence (and its
/// property test) depends on it.
#[inline]
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    a: &Matrix,
    bpack: &[f64],
    c: &mut Matrix,
    ic: usize,
    pc: usize,
    jc: usize,
    mc: usize,
    kc: usize,
    nc: usize,
) {
    let strips = nc.div_ceil(NR);
    for i in 0..mc {
        let arow = &a.row(ic + i)[pc..pc + kc];
        for s in 0..strips {
            let j0 = s * NR;
            let w = NR.min(nc - j0);
            let bp = &bpack[s * kc * NR..(s + 1) * kc * NR];
            // 1 x NR register accumulator micro-kernel.
            let mut acc = [0.0f64; NR];
            for (l, &al) in arow.iter().enumerate() {
                let brow = &bp[l * NR..l * NR + NR];
                for r in 0..NR {
                    acc[r] = al.mul_add(brow[r], acc[r]);
                }
            }
            let crow = &mut c.row_mut(ic + i)[jc + j0..jc + j0 + w];
            for r in 0..w {
                crow[r] += acc[r];
            }
        }
    }
}

/// Length of the B-panel packing scratch one thread needs for
/// [`gemm_tile`]. Allocate once per GEMM (serial) or per pool thread
/// (parallel); `pack_b` fully overwrites the region it reads back, so the
/// buffer never needs re-zeroing between panels.
pub(crate) const PACK_LEN: usize = KC * NC;

/// Scale C by beta with the BLAS special cases (0 overwrites even NaN/Inf
/// garbage, 1 is a no-op).
pub(crate) fn apply_beta(c: &mut Matrix, beta: f64) {
    if beta == 0.0 {
        c.data.fill(0.0);
    } else if beta != 1.0 {
        c.scale(beta);
    }
}

/// The MC×NC output tile grid of an m x n GEMM, in the serial schedule
/// order (jc outer, ic inner). Each entry is `(ic, jc, mc, nc)`.
pub(crate) fn tile_grid(m: usize, n: usize) -> Vec<(usize, usize, usize, usize)> {
    let mut tiles = Vec::with_capacity(m.div_ceil(MC) * n.div_ceil(NC));
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for ic in (0..m).step_by(MC) {
            let mc = MC.min(m - ic);
            tiles.push((ic, jc, mc, nc));
        }
    }
    tiles
}

/// Copy C[ic.., jc..] (mc x nc) into the row-major tile buffer.
pub(crate) fn load_tile(
    c: &Matrix,
    ic: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    buf: &mut Vec<f64>,
) {
    buf.clear();
    for i in 0..mc {
        buf.extend_from_slice(&c.row(ic + i)[jc..jc + nc]);
    }
}

/// Write the row-major tile buffer back into C[ic.., jc..].
pub(crate) fn store_tile(c: &mut Matrix, ic: usize, jc: usize, mc: usize, nc: usize, buf: &[f64]) {
    debug_assert_eq!(buf.len(), mc * nc);
    for i in 0..mc {
        c.row_mut(ic + i)[jc..jc + nc].copy_from_slice(&buf[i * nc..(i + 1) * nc]);
    }
}

/// Accumulate one output tile over the full k extent:
/// `tile += A[ic..ic+mc, :] * B[:, jc..jc+nc]`, `tile` row-major mc x nc,
/// `bpack` a [`PACK_LEN`]-sized per-thread packing scratch.
///
/// This is the single reference kernel every backend schedules: ascending
/// KC panels, packed B strips, 1 x NR FMA micro-kernel. The per-element
/// operation sequence is a function of (element, k) only, so any tile
/// execution order — serial or parallel — produces bitwise identical C.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_tile(
    a: &Matrix,
    b: &Matrix,
    ic: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    bpack: &mut [f64],
    tile: &mut [f64],
) {
    debug_assert_eq!(tile.len(), mc * nc);
    debug_assert!(bpack.len() >= PACK_LEN);
    let k = a.cols;
    let strips = nc.div_ceil(NR);
    for pc in (0..k).step_by(KC) {
        let kc = KC.min(k - pc);
        pack_b(b, pc, jc, kc, nc, bpack);
        for i in 0..mc {
            let arow = &a.row(ic + i)[pc..pc + kc];
            for s in 0..strips {
                let j0 = s * NR;
                let w = NR.min(nc - j0);
                let bp = &bpack[s * kc * NR..(s + 1) * kc * NR];
                // 1 x NR register accumulator micro-kernel.
                let mut acc = [0.0f64; NR];
                for (l, &al) in arow.iter().enumerate() {
                    let brow = &bp[l * NR..l * NR + NR];
                    for r in 0..NR {
                        acc[r] = al.mul_add(brow[r], acc[r]);
                    }
                }
                let crow = &mut tile[i * nc + j0..i * nc + j0 + w];
                for r in 0..w {
                    crow[r] += acc[r];
                }
            }
        }
    }
}

/// Pack B[pc..pc+kc, jc..jc+nc] into NR-wide column strips:
/// bpack[strip][l * NR + r] = B[pc+l, jc + strip*NR + r].
#[inline]
fn pack_b(b: &Matrix, pc: usize, jc: usize, kc: usize, nc: usize, bpack: &mut [f64]) {
    let strips = nc.div_ceil(NR);
    for s in 0..strips {
        let j0 = s * NR;
        let w = NR.min(nc - j0);
        let dst = &mut bpack[s * kc * NR..(s + 1) * kc * NR];
        for l in 0..kc {
            let src = b.row(pc + l);
            let d = &mut dst[l * NR..l * NR + NR];
            for r in 0..w {
                d[r] = src[jc + j0 + r];
            }
            for r in w..NR {
                d[r] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for l in 0..a.cols {
                let al = a.at(i, l);
                for j in 0..b.cols {
                    *c.at_mut(i, j) += al * b.at(l, j);
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_square() {
        let mut rng = Rng::new(3);
        for n in [1, 2, 7, 16, 33, 65, 130] {
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let c = gemm(&a, &b);
            let r = naive(&a, &b);
            let err = c.sub(&r).max_abs();
            assert!(err < 1e-12 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn matches_naive_rectangular() {
        let mut rng = Rng::new(4);
        for (m, k, n) in [(3, 300, 5), (100, 7, 260), (65, 257, 9), (1, 1, 1)] {
            let a = Matrix::uniform(m, k, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(k, n, -1.0, 1.0, &mut rng);
            let err = gemm(&a, &b).sub(&naive(&a, &b)).max_abs();
            assert!(err < 1e-11, "({m},{k},{n}) err={err}");
        }
    }

    #[test]
    fn beta_accumulates() {
        let mut rng = Rng::new(5);
        let a = Matrix::uniform(20, 30, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(30, 10, -1.0, 1.0, &mut rng);
        let mut c = Matrix::uniform(20, 10, -1.0, 1.0, &mut rng);
        let c0 = c.clone();
        gemm_into(&a, &b, &mut c, 1.0);
        let mut expect = naive(&a, &b);
        expect.add_assign(&c0);
        assert!(c.sub(&expect).max_abs() < 1e-12);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(6);
        let a = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
        let c = gemm(&a, &Matrix::identity(40));
        assert!(c.sub(&a).max_abs() == 0.0);
    }

    #[test]
    fn empty_dims() {
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        let c = gemm(&a, &b);
        assert_eq!((c.rows, c.cols), (0, 3));
    }

    #[test]
    fn tile_grid_covers_exactly() {
        for (m, n) in [(1, 1), (64, 256), (65, 257), (130, 513), (512, 512)] {
            let mut covered = vec![false; m * n];
            for (ic, jc, mc, nc) in tile_grid(m, n) {
                for i in ic..ic + mc {
                    for j in jc..jc + nc {
                        assert!(!covered[i * n + j], "({m},{n}): ({i},{j}) covered twice");
                        covered[i * n + j] = true;
                    }
                }
            }
            assert!(covered.iter().all(|&c| c), "({m},{n}): grid left holes");
        }
    }

    #[test]
    fn tile_roundtrip() {
        let mut rng = Rng::new(7);
        let mut c = Matrix::uniform(10, 9, -1.0, 1.0, &mut rng);
        let orig = c.clone();
        let mut buf = Vec::new();
        load_tile(&c, 2, 3, 5, 4, &mut buf);
        assert_eq!(buf.len(), 20);
        store_tile(&mut c, 2, 3, 5, 4, &buf);
        assert_eq!(c, orig);
    }
}
