//! # adp-dgemm
//!
//! Reproduction of *"Guaranteed DGEMM Accuracy While Using Reduced Precision
//! Tensor Cores Through Extensions of the Ozaki Scheme"* (SCA/HPCAsia 2026).
//!
//! The library provides:
//!
//! * [`backend`] — the pluggable compute-backend layer: a
//!   [`ComputeBackend`] trait over the INT8 slice-pair kernels (the
//!   tile-major fused engine and the level-major reference) and FP64 tile
//!   kernels, with a serial reference implementation and a work-stealing
//!   parallel one (bitwise identical by construction) on a shared
//!   token-budgeted scoped-thread pool, plus the pooled [`Workspace`]
//!   scratch that makes the steady-state hot path allocation-free. The
//!   seam future SIMD/GPU/sharded backends plug into.
//! * [`ozaki`] — the Ozaki-I decomposition with the paper's **unsigned slice
//!   encoding** (two's-complement remapping, §3 of the paper), a pure-Rust
//!   INT8-slice GEMM emulation pipeline on runtime-dispatched
//!   [`ozaki::kernel`] microkernels (scalar reference + AVX2
//!   `maddubs`/`pmaddwd` packed-panel kernels, bitwise interchangeable;
//!   `ADP_FORCE_SCALAR=1` pins the reference).
//! * [`esc`] — the **Exponent Span Capacity** estimator (§4), both the exact
//!   per-dot-product formulation and the coarsened block algorithm, with the
//!   proven no-overestimate guarantee.
//! * [`coordinator`] — the **Automatic Dynamic Precision** (ADP) runtime
//!   (§5): safety scans (NaN/Inf), ESC estimation, heuristic selection
//!   between emulation and native FP64, and a batched GEMM service.
//! * [`runtime`] — the PJRT execution layer that loads AOT-compiled XLA
//!   artifacts (`artifacts/*.hlo.txt`, produced by `python/compile/aot.py`
//!   from JAX + Pallas sources) and runs them from the Rust hot path.
//! * [`linalg`] — FP64 substrates: blocked GEMM, Strassen (accuracy
//!   comparator for the grading tests), and blocked Householder QR
//!   (the cuSOLVER `geqrf` analogue of §7.3).
//! * [`grading`] — the BLAS grading tests of Demmel et al. (§6): algorithm
//!   discovery Tests 1–3 and the Grade A componentwise criterion.
//! * [`dd`] — double-double (~106-bit) arithmetic used as the extended
//!   precision reference (the paper uses FP80 long double).
//! * [`perfmodel`] — the Tensor-Core cost model used to translate measured
//!   CPU-substrate numbers into the paper's GPU-platform projections
//!   (GB200, RTX Pro 6000 Blackwell); see DESIGN.md §Substitutions.
//!
//! Python (JAX + Pallas) exists only on the compile path; the Rust binary is
//! self-contained once `make artifacts` has produced the HLO artifacts.

pub mod backend;
pub mod coordinator;
pub mod dd;
pub mod esc;
pub mod grading;
pub mod linalg;
pub mod ozaki;
pub mod perfmodel;
pub mod runtime;
pub mod util;

pub use backend::{
    BackendSpec, ComputeBackend, ParallelBackend, SerialBackend, SliceBatch, Workspace,
    WorkspacePool, WorkspaceStats,
};
pub use coordinator::adp::{AdpConfig, AdpEngine, AdpOutcome, GemmDecision};
pub use coordinator::costmodel::{CostModel, LearnedHeuristic};
pub use coordinator::plan::EscPlanCache;
pub use esc::{coarse_esc_gemm, exact_esc_dot, exact_esc_gemm, EscReport};
pub use linalg::matrix::Matrix;
pub use ozaki::batched::SliceCache;
pub use ozaki::{AccuracyTier, KernelId, OzakiConfig, PairSchedule, SliceEncoding, SliceKernel};
