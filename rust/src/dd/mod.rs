//! Double-double (~106-bit significand) arithmetic.
//!
//! The paper computes reference diagonal entries with FP80 `long double`
//! (§6, Fig 2); x86-80-bit floats are not expressible in Rust, so we use
//! error-free transformations (Dekker/Knuth two_sum, FMA two_prod) to build
//! a strictly more accurate ~106-bit reference. Used for:
//!
//! * `C_ref` in the grading tests (componentwise error denominators),
//! * the `x^T x` diagonal reference of Test 2,
//! * validating the native FP64 substrates themselves.

/// Unevaluated sum `hi + lo` with `|lo| <= ulp(hi)/2`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dd {
    pub hi: f64,
    pub lo: f64,
}

/// Error-free sum: a + b = s + e exactly (Knuth two_sum, no branch).
#[inline]
pub fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let bb = s - a;
    let e = (a - (s - bb)) + (b - bb);
    (s, e)
}

/// Error-free sum assuming |a| >= |b| (Dekker fast_two_sum).
#[inline]
pub fn fast_two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let e = b - (s - a);
    (s, e)
}

/// Error-free product: a * b = p + e exactly (via FMA).
#[inline]
pub fn two_prod(a: f64, b: f64) -> (f64, f64) {
    let p = a * b;
    let e = a.mul_add(b, -p);
    (p, e)
}

impl Dd {
    pub const ZERO: Dd = Dd { hi: 0.0, lo: 0.0 };

    #[inline]
    pub fn from(x: f64) -> Dd {
        Dd { hi: x, lo: 0.0 }
    }

    #[inline]
    pub fn add(self, other: Dd) -> Dd {
        let (s, e) = two_sum(self.hi, other.hi);
        let e = e + self.lo + other.lo;
        let (hi, lo) = fast_two_sum(s, e);
        Dd { hi, lo }
    }

    #[inline]
    pub fn add_f64(self, x: f64) -> Dd {
        let (s, e) = two_sum(self.hi, x);
        let e = e + self.lo;
        let (hi, lo) = fast_two_sum(s, e);
        Dd { hi, lo }
    }

    /// self + a*b with the product expanded error-free first.
    #[inline]
    pub fn add_prod(self, a: f64, b: f64) -> Dd {
        let (p, pe) = two_prod(a, b);
        let (s, se) = two_sum(self.hi, p);
        let e = se + self.lo + pe;
        let (hi, lo) = fast_two_sum(s, e);
        Dd { hi, lo }
    }

    #[inline]
    pub fn sub(self, other: Dd) -> Dd {
        self.add(Dd { hi: -other.hi, lo: -other.lo })
    }

    #[inline]
    pub fn mul(self, other: Dd) -> Dd {
        let (p, pe) = two_prod(self.hi, other.hi);
        let e = pe + self.hi * other.lo + self.lo * other.hi;
        let (hi, lo) = fast_two_sum(p, e);
        Dd { hi, lo }
    }

    #[inline]
    pub fn to_f64(self) -> f64 {
        self.hi + self.lo
    }

    #[inline]
    pub fn abs(self) -> Dd {
        if self.hi < 0.0 || (self.hi == 0.0 && self.lo < 0.0) {
            Dd { hi: -self.hi, lo: -self.lo }
        } else {
            self
        }
    }
}

/// Dot product of two f64 slices in double-double precision.
pub fn dot(x: &[f64], y: &[f64]) -> Dd {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = Dd::ZERO;
    for (&a, &b) in x.iter().zip(y) {
        acc = acc.add_prod(a, b);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_sum_exact() {
        let (s, e) = two_sum(1e16, 1.0);
        assert_eq!(s + e, 1e16 + 1.0);
        assert_eq!(s, 1e16); // 1.0 lost in f64...
        assert_eq!(e, 1.0); // ...recovered in the error term
    }

    #[test]
    fn two_prod_exact() {
        let a = 1.0 + 2f64.powi(-30);
        let b = 1.0 - 2f64.powi(-30);
        let (p, e) = two_prod(a, b);
        // a*b = 1 - 2^-60 exactly; p rounds to 1.0, e = -2^-60
        assert_eq!(p, 1.0);
        assert_eq!(e, -(2f64.powi(-60)));
    }

    #[test]
    fn dd_add_carries_low_bits() {
        let mut acc = Dd::ZERO;
        for _ in 0..1_000_000 {
            acc = acc.add_f64(0.1);
        }
        // plain f64 accumulation drifts by ~1e-9 here; dd stays exact to ulp
        assert!((acc.to_f64() - 100_000.0).abs() < 1e-9);
    }

    #[test]
    fn dot_cancellation() {
        // x.y = 0 exactly despite huge intermediate terms
        let x = [1e200, 1.0, -1e200];
        let y = [1.0, 1.0, 1.0];
        let d = dot(&x, &y);
        assert_eq!(d.to_f64(), 1.0);
    }

    #[test]
    fn mul_matches_exact() {
        let a = Dd::from(3.0).mul(Dd::from(1.0 / 3.0));
        assert!((a.to_f64() - 1.0).abs() < 1e-31 * 10.0);
    }

    #[test]
    fn abs_negates_pair() {
        let d = Dd { hi: -2.0, lo: -1e-20 };
        let a = d.abs();
        assert_eq!(a.hi, 2.0);
        assert_eq!(a.lo, 1e-20);
    }
}
